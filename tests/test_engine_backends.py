"""Cross-backend agreement: every registered backend returns bit-identical
verdicts on the generator zoo, and verdicts are invariant to the padding
bucket a request lands in."""
import numpy as np
import pytest

from repro.core import generators as G
from repro.engine import ChordalityEngine, backend_names

# The zoo: mixed sizes (hits several n_pad buckets) and mixed classes with
# known chordality — cycles non-chordal (n >= 4), the rest chordal except
# sparse_random (verdict varies; the agreement assertion is what matters).
def _zoo():
    return [
        G.random_chordal(21, k=3, subset_p=0.8, seed=0),
        G.cycle(7),
        G.sparse_random(33, avg_degree=5, seed=1),
        G.random_tree(18, seed=2),
        G.random_chordal(45, k=4, subset_p=1.0, seed=3),
        G.cycle(30),
        G.sparse_random(12, avg_degree=4, seed=4),
        G.random_tree(50, seed=5),
        G.cycle(4),
    ]


def _reference_verdicts():
    eng = ChordalityEngine(backend="jax_faithful", max_batch=4)
    return eng.run(_zoo()).verdicts


@pytest.fixture(scope="module")
def ref_verdicts():
    return _reference_verdicts()


@pytest.mark.parametrize(
    "backend", [b for b in backend_names() if b != "jax_faithful"])
def test_backend_agrees_with_faithful_on_zoo(backend, ref_verdicts):
    got = ChordalityEngine(backend=backend, max_batch=4).run(_zoo()).verdicts
    np.testing.assert_array_equal(got, ref_verdicts)


def test_zoo_known_answers(ref_verdicts):
    # Sanity-anchor the reference itself (indices per _zoo above).
    v = ref_verdicts.tolist()
    assert v[0] and v[3] and v[4] and v[7]      # chordal classes
    assert not v[1] and not v[5] and not v[8]   # cycles


@pytest.mark.parametrize("backend", ["jax_faithful", "jax_fast"])
def test_fast_orders_bit_identical(backend):
    """lexbfs_fast must produce the same PEO/witness, not just verdicts."""
    eng = ChordalityEngine(backend=backend)
    ref = ChordalityEngine(backend="jax_faithful")
    for g in (_zoo()[0], _zoo()[1], _zoo()[4]):
        a = eng.certificate(g)
        b = ref.certificate(g)
        assert a.chordal == b.chordal
        assert a.n_violations == b.n_violations
        np.testing.assert_array_equal(a.order, b.order)


# ---------------------------------------------------------------------------
# Padding invariance: same graph, different bucket grids -> same verdict.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", sorted(backend_names()))
@pytest.mark.parametrize("buckets", [(16, 32, 64, 128), (64, 128), (128,)])
def test_verdict_invariant_across_bucket_sizes(backend, buckets):
    graphs = [G.cycle(11), G.random_chordal(13, k=3, seed=7),
              G.sparse_random(24, avg_degree=5, seed=8)]
    base = ChordalityEngine(
        backend=backend, buckets=(16, 32, 64, 128)).run(graphs).verdicts
    got = ChordalityEngine(
        backend=backend, buckets=buckets).run(graphs).verdicts
    np.testing.assert_array_equal(got, base)


@pytest.mark.parametrize("backend", sorted(backend_names()))
def test_batch_padding_slots_do_not_leak(backend):
    """A unit with empty-graph padding slots must not perturb real slots."""
    graphs = [G.cycle(9), G.clique(9), G.cycle(9)]   # batch rounds 3 -> 4
    res = ChordalityEngine(backend=backend, max_batch=4).run(graphs)
    assert res.plan.units[0].n_padding_slots == 1
    assert res.verdicts.tolist() == [False, True, False]
