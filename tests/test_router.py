"""Router: cost model, capability filtering, auto-engine plan metadata."""
import numpy as np
import pytest

from repro.core import generators as G
from repro.engine import (
    ChordalityEngine,
    DEFAULT_COST_MODEL,
    Router,
    fit_cost_model,
)
from repro.engine.router import BackendCost
from repro.graphs.structure import Graph


# ---------------------------------------------------------------------------
# Cost model mechanics
# ---------------------------------------------------------------------------
def test_cost_formula_terms():
    c = BackendCost(dispatch_us=100, per_graph_us=10, sweep_us=2,
                    n_us=1, n2_us=0.5, m_us=0.25)
    # n=4, density=0.5 (m=8), batch=2:
    # 100/2 + 10 + 2*4/2 + 1*4 + 0.5*16 + 0.25*8 = 50+10+4+4+8+2
    assert c.us_per_graph(4, 0.5, 2) == pytest.approx(78.0)


def test_batch_amortizes_dispatch_and_sweeps():
    c = DEFAULT_COST_MODEL["csr"]
    assert c.us_per_graph(256, 0.01, 32) < c.us_per_graph(256, 0.01, 1)


def test_fit_cost_model_recovers_orderings():
    # Synthetic samples from two known models; the fit must reproduce the
    # cheap/expensive ordering even if exact coefficients differ.
    true = {
        "a": BackendCost(per_graph_us=100.0),
        "b": BackendCost(per_graph_us=10.0, n2_us=0.01),
    }
    samples = []
    for name, c in true.items():
        for n in (8, 32, 128, 512):
            for b in (1, 8):
                samples.append(
                    (name, n, 0.1, b, c.us_per_graph(n, 0.1, b)))
    fitted = fit_cost_model(
        samples, feature_masks={"a": (1,), "b": (1, 4)})
    assert fitted["a"].us_per_graph(8, 0.1, 1) > \
        fitted["b"].us_per_graph(8, 0.1, 1)
    assert fitted["a"].us_per_graph(512, 0.1, 1) < \
        fitted["b"].us_per_graph(512, 0.1, 1)


# ---------------------------------------------------------------------------
# Capability filtering: never pick a backend lacking a required capability,
# no matter how cheap the cost model claims it is.
# ---------------------------------------------------------------------------
def test_choose_excludes_backends_missing_required_caps():
    model = dict(DEFAULT_COST_MODEL)
    model["sharded"] = BackendCost()          # free => always cheapest
    r = Router(cost_model=model,
               candidates=("numpy_ref", "jax_fast", "csr", "sharded"))
    assert r.choose(256, 0.1, 8) == "sharded"  # unconstrained: cheapest wins
    got = r.choose(256, 0.1, 8, require=("certificate",))
    assert got != "sharded"                    # sharded lacks certificates


def test_choose_requires_some_candidate():
    r = Router(cost_model={"sharded": BackendCost()},
               candidates=("sharded",))
    with pytest.raises(ValueError, match="certificate"):
        r.choose(64, 0.1, 1, require=("certificate",))


def test_router_rejects_candidates_without_cost_entries():
    with pytest.raises(ValueError, match="pallas_peo"):
        Router(cost_model={"csr": BackendCost()},
               candidates=("csr", "pallas_peo"))


# ---------------------------------------------------------------------------
# Regime routing with the fitted default model (plan metadata only — no
# execution, so the streams can be large).
# ---------------------------------------------------------------------------
def _edge_graph(n, c, seed):
    return G.sparse_erdos_renyi(n, c=c, seed=seed)


def test_default_model_routes_three_regimes():
    tiny = [G.cycle(10)]                                    # one-off request
    sparse = [_edge_graph(1024, 10, s) for s in range(32)]  # density ~0.01
    dense = [G.dense_random(200, p=0.4, seed=s) for s in range(32)]
    eng = ChordalityEngine(backend="auto", max_batch=32)
    plan = eng.plan(tiny + sparse + dense)
    by_npad = {u.n_pad: u.backend for u in plan.units}
    # Since the PR 6 wrapper restructure, jax_fast's dispatch floor beats
    # numpy_ref's per-graph python cost, so tiny one-off requests route
    # to jax_fast too; csr still owns the sparse-large regime.
    assert by_npad[16] == "jax_fast"       # tiny single request
    assert by_npad[1024] == "csr"          # sparse large
    assert by_npad[256] == "jax_fast"      # dense bulk
    # plan metadata exposes the choice per request
    assert plan.unit_of(0).backend == "jax_fast"
    assert plan.unit_of(1).backend == "csr"
    assert plan.unit_of(len(tiny) + len(sparse)).backend == "jax_fast"


def test_auto_run_executes_routed_plan_and_agrees():
    graphs = ([G.cycle(9)]
              + [_edge_graph(80, 5, s) for s in range(6)]
              + [G.dense_random(48, p=0.5, seed=s) for s in range(6)])
    auto = ChordalityEngine(backend="auto", max_batch=8)
    res = auto.run(graphs)
    ref = ChordalityEngine(backend="numpy_ref", max_batch=8).run(graphs)
    np.testing.assert_array_equal(res.verdicts, ref.verdicts)
    assert sum(res.stats.backend_histogram.values()) == len(graphs)
    assert set(res.stats.backend_histogram) == \
        {u.backend for u in res.plan.units}


def test_auto_certificate_routes_with_certificate_requirement():
    eng = ChordalityEngine(backend="auto")
    cert = eng.certificate(G.cycle(9))
    assert not cert.chordal and cert.n_violations > 0
    cert = eng.certificate(G.k_tree(24, k=3, seed=0))
    assert cert.chordal and cert.n_violations == 0


def test_auto_rejects_backend_opts():
    with pytest.raises(ValueError, match="auto"):
        ChordalityEngine(backend="auto", interpret=False)


def test_auto_warmup_requires_plan():
    eng = ChordalityEngine(backend="auto")
    with pytest.raises(ValueError, match="warmup_plan"):
        eng.warmup([16])


def test_auto_warmup_plan_precompiles_routed_shapes():
    graphs = [G.cycle(10), G.dense_random(40, p=0.5, seed=0)]
    eng = ChordalityEngine(backend="auto", max_batch=4)
    eng.warmup_plan(eng.plan(graphs))
    res = eng.run(graphs)
    assert res.stats.compile_misses == 0


def test_custom_router_overrides_choice():
    # A router that prices everything except csr at infinity.
    model = {
        "csr": BackendCost(),
        "jax_fast": BackendCost(per_graph_us=1e12),
        "numpy_ref": BackendCost(per_graph_us=1e12),
    }
    eng = ChordalityEngine(
        backend="auto", max_batch=4, router=Router(cost_model=model))
    res = eng.run([G.cycle(8), G.clique(8)])
    assert res.stats.backend_histogram == {"csr": 2}
    assert res.verdicts.tolist() == [False, True]


# ---------------------------------------------------------------------------
# Degenerate inputs: the cost model must not extrapolate below its fitted
# support — tiny n, zero-edge graphs, and batch=1 route like the nearest
# measured regime (ISSUE 3 satellite; clamp_features).
# ---------------------------------------------------------------------------
def test_choose_clamps_n_below_fitted_floor():
    r = Router()
    lo, _ = r.fit_n_range
    floor_choice = r.choose(lo, 0.0, 1)
    for n in (1, 2, 3, 5, lo - 1):
        assert r.choose(n, 0.0, 1) == floor_choice
    # Unclamped extrapolation used to hand these to csr; since the PR 6
    # wrapper restructure dropped jax_fast's dispatch floor below
    # numpy_ref's per-graph python cost, the measured floor regime
    # belongs to jax_fast.
    assert floor_choice == "jax_fast"


def test_choose_clamps_degenerate_density_and_batch():
    r = Router()
    # density > 1 (bogus caller math) and batch=0 must not blow up, and
    # must agree with their clamped twins.
    assert r.choose(4, 5.0, 0) == r.choose(16, 1.0, 1)
    assert r.choose(64, float("nan"), 1) == r.choose(64, 0.0, 1)
    assert r.choose(10 ** 9, 0.0, 8) == r.choose(r.fit_n_range[1], 0.0, 8)


def test_clamp_features_bounds():
    r = Router()
    lo, hi = r.fit_n_range
    assert r.clamp_features(1, -0.5, 0) == (lo, 0.0, 1)
    assert r.clamp_features(10 ** 9, 2.0, 7) == (hi, 1.0, 7)
    n, d, b = r.clamp_features(64, 0.25, 4)
    assert (n, d, b) == (64, 0.25, 4)      # in-range points untouched


def test_router_rejects_invalid_fit_range():
    with pytest.raises(ValueError, match="fit_n_range"):
        Router(fit_n_range=(0, 16))
    with pytest.raises(ValueError, match="fit_n_range"):
        Router(fit_n_range=(32, 16))


def test_degenerate_streams_execute_on_routed_backends():
    # n smaller than every bucket, zero-edge graphs, batch=1 — end to end
    # through the auto engine, agreeing with the reference.
    graphs = [
        G.cycle(3),                                    # n=3 < smallest bucket
        Graph(n_nodes=2, adj=np.zeros((2, 2), dtype=bool)),   # zero edges
        Graph(n_nodes=1, adj=np.zeros((1, 1), dtype=bool)),   # single vertex
        Graph(n_nodes=5, edges=np.zeros((2, 0), dtype=np.int32)),  # edge view
    ]
    auto = ChordalityEngine(backend="auto", max_batch=4)
    ref = ChordalityEngine(backend="numpy_ref", max_batch=4)
    for g in graphs:                                   # batch=1 plans
        np.testing.assert_array_equal(
            auto.run([g]).verdicts, ref.run([g]).verdicts)
    res = auto.run(graphs)
    np.testing.assert_array_equal(res.verdicts, ref.run(graphs).verdicts)
    for unit in res.plan.units:
        assert unit.backend in auto.router.candidates


def test_zero_edge_certificate_routes_to_capable_backend():
    eng = ChordalityEngine(backend="auto")
    cert = eng.certificate(np.zeros((3, 3), dtype=bool))
    assert cert.chordal and cert.n_violations == 0


def test_routing_density_uses_edge_views_without_densifying():
    # Graphs that carry only an edge list: planning must not densify them.
    g = G.sparse_erdos_renyi(512, c=6, seed=0)
    lean = Graph(n_nodes=g.n_nodes, edges=g.edges)
    eng = ChordalityEngine(backend="auto", max_batch=8)
    plan = eng.plan([lean] * 8)
    (unit,) = plan.units
    assert unit.backend == "csr"
    assert lean.adj is None               # still no dense view materialized

# ---------------------------------------------------------------------------
# Online refit (ISSUE 5 satellite): a session re-fits its router from its
# own measured unit latencies, and the refit clamps the fitted support so
# routing never extrapolates outside the n-range it actually measured.
# ---------------------------------------------------------------------------
def _run_streams(eng, ns=(64, 256), passes=3):
    for _ in range(passes):
        for n in ns:
            eng.run([_edge_graph(n, 6, s) for s in range(8)])


def test_refit_router_updates_model_and_clamps_support():
    eng = ChordalityEngine(backend="auto", max_batch=8)
    before = {k: v for k, v in eng.router.cost_model.items()}
    _run_streams(eng)
    refitted = eng.refit_router(min_samples=2)
    assert refitted                       # at least one backend re-fitted
    for name in refitted:
        assert eng.router.cost_model[name] != before[name]
    # support clamp: exactly the observed n_pad range
    assert eng.router.fit_n_range == (64, 256)


def test_refit_never_routes_outside_fitted_support():
    eng = ChordalityEngine(backend="auto", max_batch=8)
    _run_streams(eng)
    eng.refit_router(min_samples=2)
    r = eng.router
    lo, hi = r.fit_n_range
    # Any query outside the measured range routes exactly like the nearest
    # measured regime — the refitted linear forms are never evaluated on
    # unfitted features.
    for d, b in ((0.0, 1), (0.02, 8), (0.5, 4)):
        assert r.choose(1, d, b) == r.choose(lo, d, b)
        assert r.choose(10 ** 9, d, b) == r.choose(hi, d, b)
        assert r.clamp_features(hi * 16, d, b)[0] == hi


def test_refit_keeps_unmeasured_backends_at_prior_coefficients():
    eng = ChordalityEngine(backend="auto", max_batch=8)
    _run_streams(eng, ns=(64,), passes=2)
    prior_csr = eng.router.cost_model["csr"]
    eng.refit_router(min_samples=10 ** 6)   # nobody reaches the bar
    assert eng.router.cost_model["csr"] == prior_csr


def test_refit_requires_auto_engine():
    eng = ChordalityEngine(backend="jax_fast")
    with pytest.raises(ValueError, match="auto"):
        eng.refit_router()


def test_refit_with_single_n_samples_keeps_prior_model_and_support():
    # Regression (ISSUE 8 bugfix): a sample log with only one distinct n
    # cannot identify the cost model's n-slope. Pre-fix, the refit fitted
    # anyway and collapsed fit_n_range to (64, 64) — every later query
    # clamped to n=64 and, e.g., big sparse graphs misrouted to jax_fast.
    eng = ChordalityEngine(backend="auto", max_batch=8)
    _run_streams(eng, ns=(64,), passes=3)
    prior_model = dict(eng.router.cost_model)
    prior_range = eng.router.fit_n_range
    assert eng.refit_router(min_samples=2) == ()
    assert eng.router.cost_model == prior_model
    assert eng.router.fit_n_range == prior_range
    # routing for far-away n is untouched by the degenerate log
    fresh = Router()
    for d, b in ((0.005, 8), (0.3, 4)):
        assert eng.router.choose(1024, d, b) == fresh.choose(1024, d, b)
    # explicit opt-in overrides the distinct-n bar, but even then a
    # single-n fit must not collapse the support interval
    refitted = eng.refit_router(min_samples=2, min_distinct_n=1)
    assert refitted
    assert eng.router.fit_n_range == prior_range


def test_stats_surface_unit_samples():
    eng = ChordalityEngine(backend="auto", max_batch=8)
    res = eng.run([_edge_graph(64, 6, s) for s in range(8)])
    assert len(res.stats.unit_samples) == res.stats.n_units
    name, n, density, batch, device_count, us = res.stats.unit_samples[0]
    assert name in eng.router.candidates
    assert n == 64 and batch == 8
    assert 0.0 < density < 1.0 and us > 0.0
    # auto candidates are all single-device backends
    assert device_count == 1


# ---------------------------------------------------------------------------
# device_count feature (PR 10): mesh-aware pricing, clamped to fitted
# support so single-device logs never extrapolate multi-device costs.
# ---------------------------------------------------------------------------
def test_us_per_graph_device_count_divides_compute_terms():
    c = BackendCost(dispatch_us=100, per_graph_us=10, sweep_us=2,
                    n_us=1, n2_us=0.5, m_us=0.25, dev_us=3, max_devices=8)
    # d=1 recovers the legacy form exactly (test_cost_formula_terms)
    assert c.us_per_graph(4, 0.5, 2, device_count=1) == pytest.approx(78.0)
    # d=4: compute terms (4 + 8 + 2) divide by 4, coordination adds 3*(4-1)
    assert c.us_per_graph(4, 0.5, 2, device_count=4) == pytest.approx(
        50 + 10 + 4 + (4 + 8 + 2) / 4 + 9)
    # past the fitted span the entry clamps to its own max_devices
    assert c.us_per_graph(4, 0.5, 2, device_count=64) == \
        c.us_per_graph(4, 0.5, 2, device_count=8)


def test_device_count_is_inert_for_single_device_entries():
    # Every committed default entry is a single-device fit
    # (max_devices=1): pricing with a mesh width must change nothing.
    for c in DEFAULT_COST_MODEL.values():
        assert c.us_per_graph(256, 0.1, 8, device_count=8) == \
            c.us_per_graph(256, 0.1, 8)


def test_clamp_features_clamps_device_count_to_fitted_support():
    # The satellite fix: a router whose model was fitted single-device
    # (the default) must clamp device_count to 1 rather than price a
    # mesh width nobody measured.
    r = Router()
    assert r.fit_device_range == (1, 1)
    assert r.clamp_features(256, 0.1, 8, 8) == (256, 0.1, 8, 1)
    # a router fitted over a real device span passes it through...
    r8 = Router(fit_device_range=(1, 8))
    assert r8.clamp_features(256, 0.1, 8, 8) == (256, 0.1, 8, 8)
    # ...and clamps past its edges
    assert r8.clamp_features(256, 0.1, 8, 64)[3] == 8
    assert r8.clamp_features(256, 0.1, 8, 0)[3] == 1
    # the 3-feature surface is unchanged (pre-PR 10 callers)
    assert r.clamp_features(256, 0.1, 8) == (256, 0.1, 8)


def test_router_rejects_invalid_fit_device_range():
    with pytest.raises(ValueError, match="fit_device_range"):
        Router(fit_device_range=(0, 8))
    with pytest.raises(ValueError, match="fit_device_range"):
        Router(fit_device_range=(8, 1))


def test_platform_overlay_prices_sharded_mesh():
    from repro.engine.router import platform_cost_model

    # The bare default model carries no sharded entry; the cpu overlay
    # does (fitted from the emulated-mesh scaling bench).
    assert "sharded" not in DEFAULT_COST_MODEL
    cpu = platform_cost_model("cpu")
    assert "sharded" in cpu and cpu["sharded"].max_devices == 8
    r = Router(platform="cpu",
               candidates=("numpy_ref", "jax_fast", "csr", "sharded"),
               fit_device_range=(1, 8))
    est = r.estimate_us_per_graph
    # more devices -> cheaper big dense units, never more expensive
    assert est("sharded", 1024, 0.3, 32, device_count=8) < \
        est("sharded", 1024, 0.3, 32, device_count=1)
    # single-device, sharded never undercuts the plain jit path it wraps
    assert est("sharded", 256, 0.1, 8, device_count=1) >= \
        est("jax_fast", 256, 0.1, 8)


def test_fit_cost_model_learns_device_terms():
    true = BackendCost(dispatch_us=120, per_graph_us=2, n_us=0.4,
                       n2_us=0.01, dev_us=15, max_devices=8)
    samples = [
        ("sharded", n, 0.1, b, d, true.us_per_graph(n, 0.1, b, d))
        for n in (64, 256, 1024) for b in (8, 32) for d in (1, 2, 4, 8)
    ]
    fitted = fit_cost_model(samples)["sharded"]
    assert fitted.max_devices == 8
    for n, b, d in ((128, 16, 1), (512, 8, 4), (1024, 32, 8)):
        assert fitted.us_per_graph(n, 0.1, b, d) == pytest.approx(
            true.us_per_graph(n, 0.1, b, d), rel=0.05)
    # legacy 5-field rows still fit (at device_count=1, max_devices=1)
    legacy = fit_cost_model(
        [("jax_fast", n, 0.1, 8, DEFAULT_COST_MODEL["jax_fast"]
          .us_per_graph(n, 0.1, 8)) for n in (64, 128, 256, 512)])
    assert legacy["jax_fast"].max_devices == 1


def test_refit_clamps_device_support_to_observed_single_device():
    # Live logs from a single-device session must narrow the device
    # support to (1, 1) — even on a router that started mesh-capable.
    eng = ChordalityEngine(
        backend="auto", max_batch=8,
        router=Router(fit_device_range=(1, 8)))
    _run_streams(eng)
    assert eng.refit_router(min_samples=2)
    assert eng.router.fit_device_range == (1, 1)
    assert eng.router.clamp_features(256, 0.1, 8, 8)[3] == 1
