"""Shape sweep: peo_check Pallas kernel vs pure-jnp oracle (ref.py)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import generators as G
from repro.core.lexbfs import lexbfs
from repro.core.peo import peo_check
from repro.kernels.peo_check.ops import peo_check_pallas, peo_violations_count
from repro.kernels.peo_check.ref import parents_ref, violations_ref
from repro.kernels.peo_check.peo_check import peo_parents_pallas


@pytest.mark.parametrize("n", [8, 64, 128, 129, 200, 256, 300, 517])
@pytest.mark.parametrize("p", [0.1, 0.5, 0.9])
def test_violation_count_matches_ref(n, p):
    adj = G.gnp(n, p, seed=n * 7 + int(p * 10)).adj
    order = np.random.default_rng(n).permutation(n).astype(np.int32)
    got = int(peo_violations_count(jnp.asarray(adj), jnp.asarray(order)))
    want = int(violations_ref(jnp.asarray(adj), jnp.asarray(order)))
    assert got == want


@pytest.mark.parametrize("block", [(64, 64), (128, 128), (128, 256)])
def test_block_shape_sweep(block):
    bv, bz = block
    adj = G.gnp(333, 0.4, seed=1).adj
    order = np.asarray(lexbfs(jnp.asarray(adj)))
    got = int(
        peo_violations_count(
            jnp.asarray(adj), jnp.asarray(order), block_v=bv, block_z=bz
        )
    )
    want = int(violations_ref(jnp.asarray(adj), jnp.asarray(order)))
    assert got == want


@pytest.mark.parametrize("n", [16, 130, 384])
def test_parents_match_ref(n):
    adj = G.gnp(n, 0.3, seed=n).adj
    order = np.random.default_rng(0).permutation(n).astype(np.int32)
    pos = np.empty(n, dtype=np.int32)
    pos[order] = np.arange(n, dtype=np.int32)
    p_pal, best_pal = peo_parents_pallas(
        jnp.asarray(adj, jnp.int8), jnp.asarray(pos)
    )
    p_ref, best_ref = parents_ref(jnp.asarray(adj), jnp.asarray(pos))
    # Rows with no left-neighbor: p is arbitrary-but-masked; compare only
    # where best >= 0, plus assert the best positions agree everywhere.
    np.testing.assert_array_equal(np.asarray(best_pal), np.asarray(best_ref))
    has = np.asarray(best_ref) >= 0
    np.testing.assert_array_equal(
        np.asarray(p_pal)[has], np.asarray(p_ref)[has]
    )


@pytest.mark.parametrize("seed", range(5))
def test_full_pipeline_agreement(seed):
    """LexBFS + Pallas PEO == LexBFS + jnp PEO == chordality verdict."""
    n = 150
    adj = G.gnp(n, 0.25, seed=seed).adj
    order = lexbfs(jnp.asarray(adj))
    assert bool(peo_check_pallas(jnp.asarray(adj), order)) == bool(
        peo_check(jnp.asarray(adj), order)
    )


def test_chordal_graph_zero_violations():
    g = G.random_chordal(200, k=6, seed=0)
    order = lexbfs(jnp.asarray(g.adj))
    assert int(peo_violations_count(jnp.asarray(g.adj), order)) == 0


def test_cycle_nonzero_violations():
    adj = G.cycle(100).adj
    order = lexbfs(jnp.asarray(adj))
    assert int(peo_violations_count(jnp.asarray(adj), order)) > 0
