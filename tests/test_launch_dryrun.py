"""Launch-layer tests: sharding rules, cell builders, and a real (reduced)
dry-run in a subprocess with 512 host placeholder devices."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_smoke_mesh
from repro.launch.sharding import (
    LM_DENSE_RULES,
    param_shardings,
    spec_for,
    state_shardings,
)
from repro.models.common import ParamSpec, abstract_params

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_spec_for_divisibility_fallback():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # heads=20 does not divide 16 -> replicated; mlp=6912 divides -> sharded
    spec = spec_for(("embed", "heads", "qkv"), (2560, 20, 128),
                    LM_DENSE_RULES, mesh)
    assert spec == P("data",)  # trailing Nones trimmed
    spec = spec_for(("embed", "mlp"), (2560, 6912), LM_DENSE_RULES, mesh)
    assert spec == P("data", "model")


def test_spec_for_axis_conflict_drops_later_dim():
    mesh = _FakeMesh({"data": 4, "model": 4})
    rules = {"a": ("model",), "b": ("model",)}
    spec = spec_for(("a", "b"), (8, 8), rules, mesh)
    assert spec == P("model",)


def test_state_shardings_match_params():
    from repro.optim import make_adamw, make_adafactor, constant

    mesh = make_smoke_mesh()
    specs = {"w": ParamSpec((8, 4), (None, None)),
             "b": ParamSpec((4,), (None,))}
    pa = abstract_params(specs)
    psh = param_shardings(specs, {}, mesh)
    for make in (make_adamw, make_adafactor):
        opt = make(constant(1e-3))
        sa = jax.eval_shape(opt.init, pa)
        ssh = state_shardings(sa, psh, pa, mesh)
        # same tree structure, every leaf a NamedSharding
        assert jax.tree_util.tree_structure(ssh) == \
            jax.tree_util.tree_structure(sa)


@pytest.mark.parametrize("arch,shape", [
    ("gcn-cora", "full_graph_sm"),
    ("pna", "molecule"),
    ("dcn-v2", "serve_p99"),
    ("chordality", "sparse_10k"),
])
def test_build_cell_lowers_on_tiny_mesh(arch, shape):
    """Cell builders produce lowerable jit programs (1×1 mesh, no compile
    of the giant LMs — those are covered by the subprocess dry-run)."""
    from repro.launch.specs import build_cell

    mesh = make_smoke_mesh()
    cell = build_cell(arch, shape, mesh)
    with mesh:
        jax.jit(cell.fn, in_shardings=cell.in_shardings).lower(*cell.args)


def test_input_specs_are_abstract():
    from repro.launch.specs import input_specs

    mesh = make_smoke_mesh()
    args = input_specs("gcn-cora", "full_graph_sm", mesh)
    for leaf in jax.tree_util.tree_leaves(args):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.slow
def test_real_dryrun_subprocess_multipod():
    """The actual deliverable path: 512 host devices, (2,16,16) mesh,
    lower+compile for a small arch × two shapes."""
    out = os.path.join(REPO, "experiments", "dryrun_test")
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", "gcn-cora", "--multi-pod", "--out", out,
    ]
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        cmd, env=env, capture_output=True, text=True, timeout=540,
        cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    with open(os.path.join(
            out, "pod2_2x16x16", "gcn-cora__full_graph_sm.json")) as f:
        stats = json.load(f)
    assert stats["status"] == "ok"
    assert stats["n_chips"] == 512
    assert stats["flops"] > 0


def test_sharded_chordality_matches_unsharded():
    """make_sharded_chordality on a 1×1 mesh == plain batched verdicts."""
    from repro.core import generators as G
    from repro.core.chordality import is_chordal_batch, make_sharded_chordality
    from repro.graphs.structure import batch_graphs

    mesh = make_smoke_mesh()
    fn = make_sharded_chordality(mesh, batch_axes=("data",))
    graphs = [G.cycle(16), G.clique(16), G.random_tree(16, seed=0),
              G.random_chordal(16, k=3, seed=1)]
    adjs = jnp.asarray(batch_graphs(graphs, n_pad=16))
    with mesh:
        got = np.asarray(fn(adjs))
    want = np.asarray(is_chordal_batch(adjs))
    np.testing.assert_array_equal(got, want)


def test_collective_bytes_parser():
    from repro.launch.hlo_analysis import collective_bytes

    hlo = """
  %ar = bf16[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = f32[512]{0} all-gather(%y), dimensions={0}
  %noise = f32[8]{0} add(%a, %b)
  %a2a = (s32[16]{0}, s32[16]{0}) all-to-all(%p, %q)
  %cp = u8[1024]{0} collective-permute(%z)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 128 * 256 * 2
    assert got["all-gather"] == 512 * 4
    assert got["all-to-all"] == 2 * 16 * 4
    assert got["collective-permute"] == 1024
    assert got["count"] == 4
