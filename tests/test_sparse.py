"""repro.sparse: CSR container, padded packing, LexBFS/PEO parity.

The load-bearing invariants:
* CSRGraph round-trips dense <-> CSR and builds from every Graph view.
* Both CSR LexBFS implementations (device scan, host batched numpy) are
  BIT-IDENTICAL to the dense reference on padded inputs.
* CSR PEO violation counts equal the dense counts (same (v, z) pairs).
* Verdicts are invariant under nnz_pad / deg_pad growth (padded-CSR
  contract: sentinel edges and empty rows never change an answer).
"""
import numpy as np
import pytest

from repro.configs.shapes import engine_deg_bucket, engine_nnz_bucket
from repro.core import generators as G
from repro.core.lexbfs import lexbfs_numpy_dense
from repro.core.peo import peo_violations_numpy
from repro.graphs.structure import Graph
from repro.sparse import (
    CSRGraph,
    is_chordal_csr,
    lexbfs_csr,
    lexbfs_csr_numpy_batch,
    pack_csr_batch,
    pack_dense_batch,
    peo_violations_csr,
    peo_violations_csr_numpy_batch,
)


def _zoo():
    return [
        G.sparse_erdos_renyi(40, c=4, seed=0),
        G.cycle(23),
        G.long_cycle(37, n_chords=4, seed=1),
        G.random_tree(31, seed=2),
        G.k_tree(29, k=3, seed=3),
        G.gnp(26, 0.3, seed=4),
        G.clique(9),
        G.path(2),
        Graph(n_nodes=3),                 # empty graph, no arrays at all
    ]


# ---------------------------------------------------------------------------
# CSRGraph container
# ---------------------------------------------------------------------------
def test_csr_roundtrip_dense():
    for g in _zoo():
        g = g.with_dense()
        c = CSRGraph.from_dense(g.adj, g.n_nodes)
        np.testing.assert_array_equal(
            c.to_dense(), g.adj[: g.n_nodes, : g.n_nodes])
        # columns sorted within each row
        for v in range(c.n_nodes):
            row = c.col_idx[c.row_ptr[v]: c.row_ptr[v + 1]]
            assert (np.diff(row) > 0).all()


def test_csr_from_graph_prefers_edge_list():
    g = G.sparse_erdos_renyi(50, c=5, seed=7)
    assert g.edges is not None
    lean = Graph(n_nodes=g.n_nodes, edges=g.edges)   # no dense view at all
    c = CSRGraph.from_graph(lean)
    c_dense = CSRGraph.from_dense(g.with_dense().adj, g.n_nodes)
    np.testing.assert_array_equal(c.row_ptr, c_dense.row_ptr)
    np.testing.assert_array_equal(c.col_idx, c_dense.col_idx)


def test_csr_from_edges_dedups_and_symmetrizes():
    edges = np.array([[0, 0, 1, 2, 2], [1, 1, 0, 2, 0]], dtype=np.int32)
    c = CSRGraph.from_edges(3, edges)     # dup 0-1 both ways, loop 2-2
    want = np.zeros((3, 3), dtype=bool)
    want[0, 1] = want[1, 0] = want[0, 2] = want[2, 0] = True
    np.testing.assert_array_equal(c.to_dense(), want)
    assert c.nnz == 4 and c.n_edges == 2


def test_csr_stats():
    c = CSRGraph.from_graph(G.cycle(10))
    s = c.stats()
    assert s["n"] == 10 and s["nnz"] == 20 and s["n_edges"] == 10
    assert s["max_degree"] == 2 and s["mean_degree"] == 2.0
    assert s["density"] == pytest.approx(0.2)
    # CSR wins memory once n outgrows the fixed row_ptr overhead:
    big = CSRGraph.from_graph(G.cycle(1000)).stats()
    assert big["csr_bytes"] < big["dense_bytes"]


def test_prepadded_graph_slices_to_logical_block():
    from repro.graphs.structure import pad_graph

    g = pad_graph(G.cycle(9), 64)
    c = CSRGraph.from_graph(g)
    assert c.n_nodes == 9 and c.nnz == 18


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------
def test_pack_shapes_and_sentinels():
    csrs = [CSRGraph.from_graph(g) for g in (_zoo()[:4])]
    packed = pack_csr_batch(csrs, n_pad=64, batch=6)
    assert packed.row_ptr.shape == (6, 65)
    assert packed.col_idx.shape[0] == 6
    assert packed.nnz_pad == engine_nnz_bucket(max(c.nnz for c in csrs))
    assert packed.deg_pad == engine_deg_bucket(
        max(c.max_degree for c in csrs), 64)
    for i, c in enumerate(csrs):
        assert packed.row_ptr[i, -1] == c.nnz
        assert (packed.col_idx[i, c.nnz:] == 64).all()   # sentinel tail
    assert (packed.row_ptr[4:] == 0).all()               # empty slots
    assert (packed.col_idx[4:] == 64).all()


def test_pack_rejects_undersized_pads():
    c = CSRGraph.from_graph(G.clique(8))
    with pytest.raises(ValueError, match="deg_pad"):
        pack_csr_batch([c], n_pad=16, deg_pad=4)
    with pytest.raises(ValueError, match="nnz_pad"):
        pack_csr_batch([c], n_pad=16, nnz_pad=16)
    with pytest.raises(ValueError, match="n_pad"):
        pack_csr_batch([c], n_pad=4)


def test_pack_dense_batch_matches_per_graph_csr():
    graphs = [g.with_dense() for g in _zoo()[:3]]
    n_pad = 64
    adjs = np.zeros((3, n_pad, n_pad), dtype=bool)
    for i, g in enumerate(graphs):
        n = g.n_nodes
        adjs[i, :n, :n] = g.adj[:n, :n]
    packed = pack_dense_batch(adjs)
    for i, g in enumerate(graphs):
        c = CSRGraph.from_dense(g.adj, g.n_nodes)
        assert packed.row_ptr[i, -1] == c.nnz
        np.testing.assert_array_equal(packed.col_idx[i, : c.nnz], c.col_idx)


# ---------------------------------------------------------------------------
# LexBFS parity (bit-identical orders) and PEO count parity
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def packed_zoo():
    csrs = [CSRGraph.from_graph(g) for g in _zoo()]
    return _zoo(), pack_csr_batch(csrs, n_pad=48, batch=len(csrs) + 1)


def _dense_padded(g, n_pad):
    g = g.with_dense()
    adj = np.zeros((n_pad, n_pad), dtype=bool)
    n = g.n_nodes
    adj[:n, :n] = g.adj[:n, :n]
    return adj


def test_host_lexbfs_bit_identical_to_dense_reference(packed_zoo):
    graphs, packed = packed_zoo
    orders = lexbfs_csr_numpy_batch(
        packed.row_ptr, packed.col_idx, packed.deg_pad)
    for i, g in enumerate(graphs):
        ref = lexbfs_numpy_dense(_dense_padded(g, packed.n_pad))
        np.testing.assert_array_equal(orders[i], ref)


def test_device_lexbfs_bit_identical_to_dense_reference(packed_zoo):
    import jax

    graphs, packed = packed_zoo
    rp, ci = packed.device_arrays()
    orders = jax.vmap(
        lambda a, b: lexbfs_csr(a, b, packed.deg_pad))(rp, ci)
    for i, g in enumerate(graphs):
        ref = lexbfs_numpy_dense(_dense_padded(g, packed.n_pad))
        np.testing.assert_array_equal(np.asarray(orders[i]), ref)
    # host and device agree on the padding slot too (empty graph)
    host = lexbfs_csr_numpy_batch(
        packed.row_ptr, packed.col_idx, packed.deg_pad)
    np.testing.assert_array_equal(np.asarray(orders), host)


def test_peo_violation_counts_match_dense(packed_zoo):
    import jax

    graphs, packed = packed_zoo
    orders = lexbfs_csr_numpy_batch(
        packed.row_ptr, packed.col_idx, packed.deg_pad)
    viol_host = peo_violations_csr_numpy_batch(
        packed.row_ptr, packed.col_idx, orders)
    rp, ci = packed.device_arrays()
    import jax.numpy as jnp

    viol_dev = jax.vmap(peo_violations_csr)(rp, ci, jnp.asarray(orders))
    for i, g in enumerate(graphs):
        adj = _dense_padded(g, packed.n_pad)
        ref = peo_violations_numpy(adj, orders[i])
        assert viol_host[i] == ref
        assert int(viol_dev[i]) == ref
    assert viol_host[-1] == 0             # padding slot: empty graph


@pytest.mark.parametrize("grow_nnz,grow_deg", [(2, 1), (1, 2), (4, 4)])
def test_padded_csr_invariance(grow_nnz, grow_deg):
    """Verdict and violation count unchanged under nnz_pad/deg_pad growth."""
    graphs = [G.cycle(15), G.k_tree(20, k=3, seed=0),
              G.sparse_erdos_renyi(24, c=4, seed=1)]
    csrs = [CSRGraph.from_graph(g) for g in graphs]
    base = pack_csr_batch(csrs, n_pad=32)
    grown = pack_csr_batch(
        csrs, n_pad=32, nnz_pad=base.nnz_pad * grow_nnz,
        deg_pad=min(base.deg_pad * grow_deg, 32))
    o1 = lexbfs_csr_numpy_batch(base.row_ptr, base.col_idx, base.deg_pad)
    o2 = lexbfs_csr_numpy_batch(grown.row_ptr, grown.col_idx, grown.deg_pad)
    np.testing.assert_array_equal(o1, o2)
    v1 = peo_violations_csr_numpy_batch(base.row_ptr, base.col_idx, o1)
    v2 = peo_violations_csr_numpy_batch(grown.row_ptr, grown.col_idx, o2)
    np.testing.assert_array_equal(v1, v2)


def test_is_chordal_csr_known_classes():
    cases = [
        (G.random_tree(40, seed=0), True),
        (G.k_tree(40, k=4, seed=1), True),
        (G.cycle(4), False),
        (G.long_cycle(60), False),
        (G.clique(12), True),
    ]
    for g, want in cases:
        c = CSRGraph.from_graph(g)
        assert is_chordal_csr(c, pipeline="host") is want
        assert is_chordal_csr(c, pipeline="device") is want


# ---------------------------------------------------------------------------
# Sparse generators
# ---------------------------------------------------------------------------
def test_sparse_er_density_scales_as_c_over_n():
    g = G.sparse_erdos_renyi(400, c=6, seed=0)
    c = CSRGraph.from_graph(g)
    assert 2.0 < c.stats()["mean_degree"] < 10.0
    assert g.edges is not None            # no-densify path available


def test_long_cycle_chords():
    g = G.long_cycle(50, n_chords=5, seed=0)
    c = CSRGraph.from_graph(g)
    assert c.n_edges >= 50 and c.n_edges <= 55


def test_k_tree_edge_count_and_chordality():
    n, k = 30, 3
    g = G.k_tree(n, k=k, seed=2)
    c = CSRGraph.from_graph(g)
    assert c.n_edges == k * n - k * (k + 1) // 2
    assert is_chordal_csr(c)


def test_sparse_classes_registry():
    for name, gen in G.SPARSE_CLASSES.items():
        g = gen(30)
        assert g.n_nodes == 30, name


# ---------------------------------------------------------------------------
# Acceptance: csr agrees with numpy_ref on >= 200 generated graphs
# (chordal and non-chordal, n up to 512), through the engine.
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_csr_agrees_with_numpy_ref_on_200_graphs():
    from repro.engine import ChordalityEngine

    rng = np.random.default_rng(2025)
    gens = [
        lambda n, s: G.random_tree(n, seed=s),
        lambda n, s: G.long_cycle(n, n_chords=int(n // 16), seed=s),
        lambda n, s: G.k_tree(n, k=int(rng.integers(2, 5)), seed=s),
        lambda n, s: G.sparse_erdos_renyi(n, c=float(rng.uniform(2, 8)),
                                          seed=s),
        lambda n, s: G.cycle(n),
        lambda n, s: G.gnp(n, 0.15, seed=s),
    ]
    graphs = []
    # Mostly small (fast), a tail up to n=512; few distinct buckets keep
    # the compile bill bounded.
    for s in range(200):
        if s % 25 == 0:
            n = int(rng.integers(300, 513))
        else:
            n = int(rng.integers(4, 97))
        graphs.append(gens[s % len(gens)](n, s))
    csr = ChordalityEngine(backend="csr", max_batch=32).run(graphs)
    ref = ChordalityEngine(backend="numpy_ref", max_batch=32).run(graphs)
    np.testing.assert_array_equal(csr.verdicts, ref.verdicts)
    # the stream genuinely mixes verdicts
    assert 20 < csr.verdicts.sum() < 180
