"""Tests for the beyond-paper lazy-compaction LexBFS (§Perf A2/A3)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import generators as G
from repro.core.chordality import is_chordal, is_chordal_fast
from repro.core.lexbfs import lexbfs, lexbfs_fast
from repro.core.properties import is_chordal_bruteforce


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=64),
    p=st.floats(min_value=0.05, max_value=0.95),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_property_fast_order_identical_to_faithful(n, p, seed):
    """Lazy compaction is order-isomorphic ⇒ bit-identical orders."""
    adj = jnp.asarray(G.gnp(n, p, seed=seed).adj)
    np.testing.assert_array_equal(
        np.asarray(lexbfs(adj)), np.asarray(lexbfs_fast(adj)))


@pytest.mark.parametrize("n", [1, 2, 3, 29, 64, 100])
def test_fast_edge_sizes(n):
    """k_inner boundary cases incl. n smaller than one inner block."""
    adj = jnp.asarray(G.sparse_random(n, avg_degree=4, seed=n).adj
                      if n > 2 else np.zeros((n, n), bool))
    got = np.asarray(lexbfs_fast(adj))
    assert sorted(got.tolist()) == list(range(n))
    np.testing.assert_array_equal(got, np.asarray(lexbfs(adj)))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=3, max_value=30),
    p=st.floats(min_value=0.1, max_value=0.9),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_property_fast_chordality_matches_oracle(n, p, seed):
    adj = G.gnp(n, p, seed=seed).adj
    want = is_chordal_bruteforce(adj)
    assert bool(is_chordal_fast(jnp.asarray(adj))) == want
    assert bool(is_chordal(jnp.asarray(adj))) == want


def test_fast_on_paper_classes():
    assert bool(is_chordal_fast(jnp.asarray(G.clique(64).adj)))
    assert bool(is_chordal_fast(jnp.asarray(G.random_tree(64, seed=0).adj)))
    assert bool(is_chordal_fast(
        jnp.asarray(G.random_chordal(64, k=5, seed=0).adj)))
    assert not bool(is_chordal_fast(jnp.asarray(G.cycle(64).adj)))
    assert not bool(is_chordal_fast(
        jnp.asarray(G.dense_random(64, p=0.5, seed=0).adj)))
