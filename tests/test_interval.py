"""Tests for the beyond-paper LexBFS+ / proper-interval recognition."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import generators as G
from repro.core.interval import (
    is_proper_interval,
    is_proper_interval_bruteforce,
    lexbfs_plus,
    straight_enumeration_violations,
)
from repro.core.lexbfs import lexbfs
from repro.core.properties import has_lb_property


def _claw():
    adj = np.zeros((4, 4), dtype=bool)
    for leaf in (1, 2, 3):
        adj[0, leaf] = adj[leaf, 0] = True
    return adj


# Known answers --------------------------------------------------------------
@pytest.mark.parametrize("n", [2, 4, 9])
def test_paths_are_proper_interval(n):
    assert bool(is_proper_interval(jnp.asarray(G.path(n).adj)))


@pytest.mark.parametrize("n", [3, 6, 12])
def test_cliques_are_proper_interval(n):
    assert bool(is_proper_interval(jnp.asarray(G.clique(n).adj)))


def test_claw_is_not_proper_interval():
    # unit interval graphs are claw-free
    assert not bool(is_proper_interval(jnp.asarray(_claw())))


@pytest.mark.parametrize("n", [4, 5, 7])
def test_cycles_are_not_proper_interval(n):
    assert not bool(is_proper_interval(jnp.asarray(G.cycle(n).adj)))


def test_disjoint_paths_are_proper_interval():
    adj = np.zeros((6, 6), dtype=bool)
    for a, b in [(0, 1), (1, 2), (3, 4), (4, 5)]:
        adj[a, b] = adj[b, a] = True
    assert bool(is_proper_interval(jnp.asarray(adj)))


# LexBFS+ is still a LexBFS -------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=20),
    p=st.floats(min_value=0.1, max_value=0.9),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_property_lexbfs_plus_satisfies_lb(n, p, seed):
    adj = G.gnp(n, p, seed=seed).adj
    s1 = lexbfs(jnp.asarray(adj))
    s2 = np.asarray(lexbfs_plus(jnp.asarray(adj), s1))
    assert sorted(s2.tolist()) == list(range(n))
    assert has_lb_property(adj, s2)


# Against the brute-force oracle ----------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=7),
    p=st.floats(min_value=0.1, max_value=0.9),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_property_matches_bruteforce(n, p, seed):
    adj = G.gnp(n, p, seed=seed).adj
    got = bool(is_proper_interval(jnp.asarray(adj)))
    want = is_proper_interval_bruteforce(adj)
    assert got == want


def test_straight_enum_violation_counts():
    # path in path order: 0 violations
    adj = G.path(5).adj
    order = jnp.arange(5, dtype=jnp.int32)
    assert int(straight_enumeration_violations(
        jnp.asarray(adj), order)) == 0
    # claw in any order has >= 1 violation
    viol = int(straight_enumeration_violations(
        jnp.asarray(_claw()), jnp.arange(4, dtype=jnp.int32)))
    assert viol > 0
